"""Per-arch smoke tests: reduced config, one forward + one train step on
CPU, asserting output shapes and finiteness (assignment requirement)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, SMOKE_SHAPE, smoke_config
from repro.models import transformer as tfm
from repro.optim import AdamWConfig, init_opt_state
from repro.train.steps import make_train_step


def _batch(cfg, key, B, S):
    if cfg.input_kind == "tokens":
        return {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
                "labels": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    return {"embeds": jax.random.normal(key, (B, S, cfg.d_model)),
            "labels": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(arch, local_mesh):
    cfg = smoke_config(arch)
    key = jax.random.PRNGKey(0)
    params = tfm.init_model(cfg, key)
    B, S = 2, 32
    batch = _batch(cfg, key, B, S)
    logits, _, aux = tfm.forward(cfg, params, batch, mode="train",
                                 mesh=local_mesh)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all()
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_one_train_step_no_nans(arch, local_mesh):
    cfg = smoke_config(arch)
    key = jax.random.PRNGKey(1)
    params = tfm.init_model(cfg, key)
    opt_cfg = AdamWConfig(lr=1e-3)
    opt = init_opt_state(params, opt_cfg)
    step = jax.jit(make_train_step(cfg, opt_cfg, mesh=local_mesh))
    batch = _batch(cfg, key, SMOKE_SHAPE.global_batch, SMOKE_SHAPE.seq_len)
    params2, opt2, metrics = step(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # parameters actually moved
    moved = any(
        float(jnp.max(jnp.abs(a.astype(jnp.float32) -
                              b.astype(jnp.float32)))) > 0
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2)))
    assert moved


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_loss_decreases_over_20_steps(arch, local_mesh):
    cfg = smoke_config(arch)
    key = jax.random.PRNGKey(2)
    params = tfm.init_model(cfg, key)
    opt_cfg = AdamWConfig(lr=3e-3)
    opt = init_opt_state(params, opt_cfg)
    step = jax.jit(make_train_step(cfg, opt_cfg, mesh=local_mesh,
                                   warmup=5, total_steps=50))
    batch = _batch(cfg, key, 2, 32)   # overfit one batch
    losses = []
    for _ in range(20):
        params, opt, metrics = step(params, opt, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0], losses
