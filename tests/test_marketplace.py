"""Multi-user grid marketplace: contention, demand pricing, isolation,
and whole-market determinism (paper §3 distributed ownership + §7 GRACE)."""
import pytest

from repro.core import (Marketplace, MarketUser, ResourceSpec,
                        SchedulerConfig, standard_market)

from conftest import crowded_market as _crowded_market
from conftest import tight_specs as _tight_specs

HOUR = 3600.0


def test_contention_loses_slot_races_and_requeues():
    """More brokers than slots: someone must lose the race for the last
    free slot, requeue, and still finish — no crash, no lost jobs."""
    market = _crowded_market()
    rep = market.run()
    assert rep.slot_races_lost > 0, "no contention observed on a 6v3 grid"
    assert rep.total_done == rep.total_jobs, rep.summary()
    # the losers requeued rather than burning out
    losers = [o for o in rep.outcomes if o.slot_races_lost > 0]
    assert losers
    assert all(o.n_done == o.n_jobs for o in losers)


def test_slot_race_does_not_burn_attempts_or_suspect_resources():
    """Races are not failures: with max_attempts=2 and heavy contention
    every job still completes (a race loss must not consume an attempt),
    and healthy-but-busy machines are not marked suspect."""
    market = _crowded_market(sched=SchedulerConfig(max_attempts=2))
    rep = market.run()
    assert rep.slot_races_lost > 0
    assert rep.total_done == rep.total_jobs, rep.summary()
    assert all(o.stall_reason is None for o in rep.outcomes)
    for engine in market.engines:
        assert all(not v.suspected for v in engine.views.values())


def test_advisor_reads_free_capacity_not_full_rate():
    """A broker's view of a resource shrinks when rivals occupy slots."""
    market = Marketplace(specs=[ResourceSpec(
        name="big", site="x", chips=1, slots=4, perf_factor=1.0,
        base_price=1.0, mtbf_hours=float("inf"))], seed=0)
    eng = market.add_user(MarketUser(name="me", deadline=10 * HOUR,
                                     budget=1e6, n_jobs=4))
    eng._refresh_views()
    full = eng.views["big"].rate()
    assert eng.views["big"].avail_slots == 4
    # rival grabs 3 of the 4 slots
    spec = market.directory.spec("big")
    st = market.directory.status("big")
    for _ in range(3):
        assert st.acquire(spec)
    eng._refresh_views()
    assert eng.views["big"].avail_slots == 1
    assert eng.views["big"].rate() == pytest.approx(full / 4)


def test_demand_responsive_price_rises_with_utilization():
    market = Marketplace(specs=_tight_specs(2, slots=2), seed=0,
                         demand_elasticity=1.0)
    idle = market.trade.quote("m0", 0.0)
    spec = market.directory.spec("m0")
    st = market.directory.status("m0")
    st.acquire(spec)
    half = market.trade.quote("m0", 0.0)
    st.acquire(spec)
    busy = market.trade.quote("m0", 0.0)
    assert idle < half < busy
    assert busy == pytest.approx(2.0 * idle)   # elasticity 1, util 1


def test_market_price_trace_reflects_load():
    """During a crowded run, the sampled mean grid quote exceeds the
    idle quote while brokers occupy the queues."""
    market = _crowded_market(demand_elasticity=1.0)
    idle = market.mean_quote(0.0)
    rep = market.run()
    assert max(p for _, p in rep.price_trace) > idle + 1e-9


def test_budget_isolation_between_users():
    """One broke user stalling must not drain nor block the others."""
    market = Marketplace(specs=_tight_specs(4), seed=1)
    market.add_user(MarketUser(name="poor", deadline=20 * HOUR, budget=0.05,
                               strategy="conservative", n_jobs=10,
                               est_seconds=1800.0))
    market.add_user(MarketUser(name="rich", deadline=20 * HOUR, budget=1e6,
                               strategy="time", n_jobs=10,
                               est_seconds=1800.0))
    rep = market.run()
    poor, rich = rep.outcomes
    assert poor.user == "poor" and rich.user == "rich"
    assert poor.n_done < poor.n_jobs          # could not afford the grid
    assert poor.spent <= 0.05 + 1e-6
    assert rich.n_done == rich.n_jobs         # unaffected by the stall
    # ledgers are disjoint: engines never share a ledger object
    e_poor, e_rich = market.engines
    assert e_poor.ledger is not e_rich.ledger
    assert e_rich.ledger.settled == pytest.approx(rich.spent)


def test_whole_market_run_is_seed_deterministic():
    r1 = standard_market(8, n_machines=10, seed=7, n_jobs=12).run()
    r2 = standard_market(8, n_machines=10, seed=7, n_jobs=12).run()
    assert r1.stable_repr() == r2.stable_repr()
    r3 = standard_market(8, n_machines=10, seed=8, n_jobs=12).run()
    assert r1.stable_repr() != r3.stable_repr()


def test_failure_market_run_is_seed_deterministic():
    """The failure path must be as reproducible as the failure-free one:
    same seed, same crashes, same requeues, byte-identical outcomes."""
    r1 = standard_market(6, n_machines=8, seed=11, n_jobs=8).run(
        failures=True)
    r2 = standard_market(6, n_machines=8, seed=11, n_jobs=8).run(
        failures=True)
    assert r1.stable_repr() == r2.stable_repr()
    r3 = standard_market(6, n_machines=8, seed=12, n_jobs=8).run(
        failures=True)
    assert r1.stable_repr() != r3.stable_repr()


def test_failed_job_requeues_without_burning_attempt():
    """A resource dying under a running job is the machine's fault, not
    the job's: with max_attempts=1 every fault-requeue would be fatal if
    it cost an attempt, yet a flaky grid still completes everything."""
    specs = [ResourceSpec(name=f"m{i}", site="x", chips=1, slots=1,
                          base_price=1.0, peak_multiplier=1.0,
                          mtbf_hours=1.0, mttr_hours=0.25)
             for i in range(4)]
    market = Marketplace(specs=specs, seed=3, noise_sigma=0.0)
    market.add_user(MarketUser(name="u", deadline=40 * HOUR, budget=1e6,
                               strategy="time", n_jobs=12,
                               est_seconds=1800.0),
                    sched_cfg=SchedulerConfig(max_attempts=1))
    rep = market.run(failures=True)
    out = rep.outcomes[0]
    assert out.resource_losses > 0, rep.summary()   # faults did happen
    assert out.n_done == out.n_jobs, rep.summary()  # none became fatal
    assert market.engines[0].ledger.committed == pytest.approx(0.0)


def test_sixteen_users_share_one_clock_and_finish():
    market = standard_market(16, n_machines=12, seed=2, n_jobs=10)
    rep = market.run()
    assert rep.n_users == 16
    assert rep.total_done == rep.total_jobs, rep.summary()
    # one shared simulator: every engine saw the same clock object
    assert len({id(e.sim) for e in market.engines}) == 1


def test_duplicate_user_rejected():
    market = Marketplace(specs=_tight_specs(2), seed=0)
    market.add_user(MarketUser(name="a", deadline=HOUR, budget=10.0))
    with pytest.raises(ValueError):
        market.add_user(MarketUser(name="a", deadline=HOUR, budget=10.0))


def test_cancel_during_dispatch_latency_never_runs():
    """A duplicate killed while its dispatch is still in the WAN hop must
    not acquire a slot, run, or fire any callback (zombie prevention)."""
    from repro.core import (DispatchCallbacks, Job, JobSpec,
                            ResourceDirectory, SimulatedExecutor, Simulator)
    sim = Simulator()
    d = ResourceDirectory()
    d.register(ResourceSpec(name="r", site="x", mtbf_hours=float("inf")))
    ex = SimulatedExecutor(sim, d, dispatch_latency=5.0, noise_sigma=0.0)
    events = []
    job = Job(spec=JobSpec(job_id="j", experiment="e", point={}, steps=(),
                           est_seconds_base=60.0))
    cb = DispatchCallbacks(on_started=lambda j: events.append("start"),
                           on_done=lambda j, s: events.append("done"),
                           on_failed=lambda j, r: events.append("fail"),
                           on_blocked=lambda j, r: events.append("blocked"))
    ex.submit(job, "r", cb)
    ex.cancel(job)              # killed before the hop lands
    sim.run()
    assert events == []
    assert d.status("r").running == 0
