"""Unit tests: loop-aware HLO analyzer + logical-axis sharding rules."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import SHAPES, get_config
from repro.distributed import sharding as shd
from repro.launch.mesh import make_local_mesh
from repro.roofline import hlo_cost
from repro.roofline.analysis import model_flops_for


# ---------------------------------------------------------------------------
# hlo_cost
# ---------------------------------------------------------------------------

def _compile(f, *specs):
    return jax.jit(f).lower(*specs).compile()


def test_scan_flops_multiplied_by_trip_count():
    def loop(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=9)
        return y
    c = _compile(loop, jax.ShapeDtypeStruct((128, 128), jnp.float32),
                 jax.ShapeDtypeStruct((128, 128), jnp.float32))
    cost = hlo_cost.analyze(c.as_text())
    expect = 9 * 2 * 128 ** 3
    assert 0.95 * expect <= cost.flops <= 1.10 * expect


def test_nested_scan_flops():
    def loop(x, w):
        def outer(c, _):
            def inner(d, _):
                return d @ w, None
            d, _ = jax.lax.scan(inner, c, None, length=3)
            return d, None
        y, _ = jax.lax.scan(outer, x, None, length=4)
        return y
    c = _compile(loop, jax.ShapeDtypeStruct((64, 64), jnp.float32),
                 jax.ShapeDtypeStruct((64, 64), jnp.float32))
    cost = hlo_cost.analyze(c.as_text())
    expect = 12 * 2 * 64 ** 3
    assert 0.9 * expect <= cost.flops <= 1.2 * expect


def test_plain_matmul_exact():
    c = _compile(lambda a, b: a @ b,
                 jax.ShapeDtypeStruct((256, 512), jnp.float32),
                 jax.ShapeDtypeStruct((512, 128), jnp.float32))
    cost = hlo_cost.analyze(c.as_text())
    assert cost.flops == pytest.approx(2 * 256 * 512 * 128, rel=1e-3)


def test_dus_not_charged_full_buffer():
    """dynamic-update-slice of a tiny slice into a huge buffer must not
    count the whole buffer as traffic.  (XLA inserts one real defensive
    copy of the undonated input — 2x buffer — but the DUS itself must add
    only ~2x the update slice, not another 2x buffer.)"""
    def f(buf, upd):
        return jax.lax.dynamic_update_slice(buf, upd, (0, 0))
    c = _compile(f, jax.ShapeDtypeStruct((4096, 4096), jnp.float32),
                 jax.ShapeDtypeStruct((1, 4096), jnp.float32))
    cost = hlo_cost.analyze(c.as_text())
    buf_bytes = 4096 * 4096 * 4
    assert cost.bytes < 2.5 * buf_bytes   # naive accounting would be ~4x


def test_type_bytes_tuple_with_comments():
    s = ("(s32[], bf16[4,8]{1,0}, /*index=2*/f32[10]{0})")
    assert hlo_cost._type_bytes(s) == 4 + 4 * 8 * 2 + 10 * 4


# ---------------------------------------------------------------------------
# sharding rules
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def mesh16():
    # abstract 16x16 mesh for rule checks (no devices needed)
    from jax.sharding import AbstractMesh
    try:                      # jax >= 0.5: (shape, axis_names)
        return AbstractMesh((16, 16), ("data", "model"))
    except TypeError:         # jax 0.4.x: ((name, size), ...)
        return AbstractMesh((("data", 16), ("model", 16)))


def test_rules_shard_divisible_dims(mesh16):
    cfg = get_config("gemma3-27b")
    r = shd.base_rules(cfg, SHAPES["train_4k"], mesh16)
    assert r["heads"] == "model"        # 32 % 16 == 0
    assert r["kv_heads"] == "model"     # 16 % 16 == 0
    assert r["mlp"] == "model"
    assert r["embed"] == "data"         # FSDP for training
    assert r["vocab"] == "model"


def test_rules_fall_back_on_indivisible(mesh16):
    cfg = get_config("llava-next-34b")
    r = shd.base_rules(cfg, SHAPES["train_4k"], mesh16)
    assert r["heads"] is None           # 56 % 16 != 0
    assert r["kv_heads"] is None        # follows heads
    cfg = get_config("recurrentgemma-2b")
    r = shd.base_rules(cfg, SHAPES["train_4k"], mesh16)
    assert r["heads"] is None           # 10 % 16
    assert r["lru"] == "model"          # 2560 % 16 == 0


def test_serving_drops_fsdp_for_small_models(mesh16):
    cfg = get_config("gemma3-1b")       # ~1GB weights: fits TP-sharded
    r = shd.base_rules(cfg, SHAPES["decode_32k"], mesh16)
    assert r["embed"] is None
    cfg = get_config("kimi-k2-1t-a32b")  # 1T params: needs FSDP even to serve
    r = shd.base_rules(cfg, SHAPES["decode_32k"], mesh16)
    assert r["embed"] == "data"


def test_spec_from_axes_no_duplicate_mesh_axes():
    rules = {"a": "model", "b": "model", "batch": ("data",)}
    spec = shd.spec_from_axes(("a", "b"), rules)
    assert spec == P("model")           # second use of "model" dropped


def test_model_flops_for_train_vs_decode():
    cfg = get_config("gemma3-1b")
    tr = model_flops_for(cfg, SHAPES["train_4k"])
    de = model_flops_for(cfg, SHAPES["decode_32k"])
    assert tr == pytest.approx(6 * cfg.param_count() * 4096 * 256)
    assert de == pytest.approx(2 * cfg.param_count() * 128)


def test_collective_parser_counts_kinds():
    text = """
ENTRY %main (p: f32[8]) -> f32[8] {
  %p = f32[8]{0} parameter(0)
  %ar = f32[8]{0} all-reduce(%p), replica_groups={}
  %ag = f32[16]{0} all-gather(%ar), dimensions={0}
  ROOT %out = f32[8]{0} reduce-scatter(%ag), dimensions={0}
}
"""
    mod = hlo_cost.HloModule(text)
    cost = mod.entry_cost()
    assert cost.coll_count["all-reduce"] == 1
    assert cost.coll_count["all-gather"] == 1
    assert cost.coll_count["reduce-scatter"] == 1
    assert cost.coll["all-reduce"] == 32
    assert cost.coll["all-gather"] == 64
    assert cost.coll["reduce-scatter"] == 64   # max(operand, result)
