"""Differential tests: the array auction clearer must equal the
retained scalar reference clearer on ARBITRARY order books — not just
the ones golden markets happen to produce.

The scalar clearer (``clear_book_reference``) expands every order into
single-slot units and walks the prefix; the array clearer
(``clear_book_arrays``) never expands, crossing on cumulative-quantity
breakpoints instead.  Equality must hold element-for-element on the
trade list AND bit-for-bit on the clearing price, including:

* exact price ties between bids (and between asks) — resolved by the
  same (-price, user) / (price, resource) lexicographic keys;
* zero-slot orders (contribute no units, must not desync the walk);
* books that cross fully, partially, or not at all.

A seeded random sweep always runs; the hypothesis sweep rides on CI
where the package is installed.
"""
import random

import pytest

from repro.core.auctions import (AuctionBid, Ask, clear_book_arrays,
                                 clear_book_reference)

np = pytest.importorskip("numpy")


def _assert_equivalent(bids, asks):
    ref = clear_book_reference(bids, asks)
    arr = clear_book_arrays(bids, asks)
    assert arr[0] == ref[0]                       # trades, exactly
    assert repr(arr[1]) == repr(ref[1])           # price, bit-for-bit
    assert arr[2:] == ref[2:]                     # k, unit counts
    assert all(isinstance(n, int) and not isinstance(n, bool)
               for _, _, n in arr[0])             # no numpy ints leak


def _random_book(rng):
    # few distinct prices -> exact ties are common, not lucky
    prices = [round(rng.uniform(0.5, 3.0), 1) for _ in range(4)]
    bids = [AuctionBid(user=f"u{rng.randrange(5)}",
                       chip_hour_price=rng.choice(prices),
                       slots=rng.randrange(0, 5),
                       valid_until=1e9)
            for _ in range(rng.randrange(0, 8))]
    asks = [Ask(resource=f"r{i}", site="s",
                chip_hour_price=rng.choice(prices),
                slots=rng.randrange(0, 5))
            for i in range(rng.randrange(0, 8))]
    return bids, asks


def test_differential_seeded_sweep():
    rng = random.Random(1234)
    for _ in range(500):
        bids, asks = _random_book(rng)
        _assert_equivalent(bids, asks)


def test_exact_tie_book_orders_identically():
    """Every bid at one price, every ask at one crossing price: the
    whole outcome hangs on the lexicographic tie-breaks."""
    bids = [AuctionBid(user=u, chip_hour_price=2.0, slots=2,
                       valid_until=1e9) for u in ("ua", "uc", "ub")]
    asks = [Ask(resource=r, site="s", chip_hour_price=2.0, slots=3)
            for r in ("rz", "rx", "ry")]
    _assert_equivalent(bids, asks)
    trades, price, k, nb, na = clear_book_arrays(bids, asks)
    assert k == 6 and price == 2.0
    # unit i of the user-ascending bid queue meets unit i of the
    # resource-ascending ask queue: ua,ua,ub,ub,uc,uc vs rx,rx,rx,ry,ry,ry
    assert trades == [("ua", "rx", 2), ("ub", "rx", 1), ("ub", "ry", 1),
                      ("uc", "ry", 2)]


def test_empty_and_degenerate_books():
    _assert_equivalent([], [])
    _assert_equivalent(
        [AuctionBid(user="u", chip_hour_price=1.0, slots=3,
                    valid_until=1e9)], [])
    _assert_equivalent(
        [], [Ask(resource="r", site="s", chip_hour_price=1.0, slots=3)])
    # all zero-slot orders: units exist on neither side
    _assert_equivalent(
        [AuctionBid(user="u", chip_hour_price=9.0, slots=0,
                    valid_until=1e9)],
        [Ask(resource="r", site="s", chip_hour_price=1.0, slots=0)])


def test_no_cross_book_clears_nothing():
    bids = [AuctionBid(user="u", chip_hour_price=1.0, slots=4,
                       valid_until=1e9)]
    asks = [Ask(resource="r", site="s", chip_hour_price=5.0, slots=4)]
    _assert_equivalent(bids, asks)
    assert clear_book_arrays(bids, asks)[0] == []


# ---------------------------------------------------------------------------
# hypothesis sweep (CI-only: the package is a CI dependency)
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                  # pragma: no cover - local runs
    HAVE_HYPOTHESIS = False


if HAVE_HYPOTHESIS:
    price = st.one_of(
        st.sampled_from([0.5, 1.0, 1.0, 2.0, 2.5]),   # dense exact ties
        st.floats(0.01, 100.0, allow_nan=False, allow_infinity=False))

    bid_lists = st.lists(
        st.builds(AuctionBid,
                  user=st.sampled_from(["u0", "u1", "u2", "u3"]),
                  chip_hour_price=price,
                  slots=st.integers(0, 6),
                  valid_until=st.just(1e9)),
        max_size=12)

    ask_lists = st.lists(
        st.builds(Ask,
                  resource=st.sampled_from(["r0", "r1", "r2", "r3"]),
                  site=st.just("s"),
                  chip_hour_price=price,
                  slots=st.integers(0, 6)),
        max_size=12)

    @settings(deadline=None, max_examples=200)
    @given(bid_lists, ask_lists)
    def test_hypothesis_array_equals_reference(bids, asks):
        _assert_equivalent(bids, asks)
