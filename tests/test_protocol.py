"""Wire-protocol round-trips: every registered message type must cross
``encode -> stable_dumps -> parse`` byte-identically, and malformed or
mis-versioned payloads must be rejected with a clear error.

The registry-driven tests always run; with ``hypothesis`` installed
(CI), fuzzed payloads stress the same contract far beyond the seed
corpus.
"""
import dataclasses
import json
import math
import typing

import pytest

from repro.core import protocol as P
from repro.core.persistence import stable_dumps


# ---------------------------------------------------------------------------
# registry-driven round-trips (always run)
# ---------------------------------------------------------------------------

def test_every_registered_type_has_an_example():
    kinds = {m.wire_kind for m in P.example_messages()}
    assert kinds == set(P.MESSAGE_TYPES)


@pytest.mark.parametrize("msg", P.example_messages(),
                         ids=lambda m: m.wire_kind)
def test_example_round_trips_byte_identically(msg):
    wire = P.dumps(msg)
    back = P.loads(wire)
    assert back == msg
    # byte-identical: re-encoding the parsed message reproduces the
    # exact wire string (the property loopback golden-equivalence needs)
    assert P.dumps(back) == wire


@pytest.mark.parametrize("msg", P.example_messages(),
                         ids=lambda m: m.wire_kind)
def test_wire_form_is_canonical_json(msg):
    wire = P.dumps(msg)
    d = json.loads(wire)
    assert d["v"] == P.PROTOCOL_VERSION
    assert d["type"] == msg.wire_kind
    assert wire == stable_dumps(d)      # sorted keys, shortest floats


def test_nonfinite_floats_survive():
    q = P.GISQuery(t=0.0, max_price=math.inf)
    assert P.loads(P.dumps(q)) == q
    q2 = P.GISQuery(t=0.0, max_price=-math.inf)
    assert P.loads(P.dumps(q2)) == q2


def test_float_fields_keep_int_values_intact():
    # JSON can't tell 2 from 2.0 — the decoder must not coerce and
    # re-encode 2 as 2.0 (that would break byte-identity)
    msg = P.QuoteRequest(resource="r", t=2, user="u")
    assert P.dumps(P.loads(P.dumps(msg))) == P.dumps(msg)


# ---------------------------------------------------------------------------
# rejection: version and shape errors must be loud and specific
# ---------------------------------------------------------------------------

def _wire_dict(msg):
    return json.loads(P.dumps(msg))


def test_rejects_unknown_version():
    d = _wire_dict(P.OkReply(ok=True))
    d["v"] = P.PROTOCOL_VERSION + 1
    with pytest.raises(P.ProtocolError, match="version"):
        P.parse(d)


def test_rejects_missing_version():
    d = _wire_dict(P.OkReply(ok=True))
    del d["v"]
    with pytest.raises(P.ProtocolError, match="version"):
        P.parse(d)


def test_rejects_malformed_version_field():
    d = _wire_dict(P.OkReply(ok=True))
    for bad in ("1", 1.5, None, [1], True):
        d["v"] = bad
        with pytest.raises(P.ProtocolError, match="version"):
            P.parse(d)


def test_rejects_unknown_message_kind():
    d = _wire_dict(P.OkReply(ok=True))
    d["type"] = "no_such_message"
    with pytest.raises(P.ProtocolError, match="no_such_message"):
        P.parse(d)


def test_rejects_missing_required_field():
    d = _wire_dict(P.QuoteRequest(resource="r", t=0.0))
    del d["resource"]
    with pytest.raises(P.ProtocolError, match="resource"):
        P.parse(d)


def test_rejects_unexpected_extra_field():
    d = _wire_dict(P.QuoteRequest(resource="r", t=0.0))
    d["bogus"] = 1
    with pytest.raises(P.ProtocolError, match="bogus"):
        P.parse(d)


def test_rejects_non_dict_payload():
    for bad in ("[]", "3", '"quote_request"'):
        with pytest.raises(P.ProtocolError):
            P.loads(bad)
    with pytest.raises(P.ProtocolError):
        P.loads("not json at all")


def test_encode_rejects_unregistered_object():
    class NotAMessage:
        wire_kind = "fake"
    with pytest.raises(P.ProtocolError):
        P.dumps(NotAMessage())


# ---------------------------------------------------------------------------
# hypothesis fuzzing (CI only — the local container has no hypothesis)
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:                     # pragma: no cover - CI has it
    given = None

if given is None:
    def test_hypothesis_available_in_ci():
        pytest.skip("hypothesis not installed; fuzz tests run in CI")
else:
    _text = st.text(min_size=0, max_size=30)
    _floats = st.one_of(
        st.floats(allow_nan=False, allow_infinity=True, width=64),
        st.integers(min_value=-10**9, max_value=10**9))
    _ints = st.integers(min_value=-10**9, max_value=10**9)

    def _strategy_for(hint):
        origin = typing.get_origin(hint)
        if hint is str:
            return _text
        if hint is float:
            return _floats
        if hint is int:
            return _ints
        if hint is bool:
            return st.booleans()
        if origin is typing.Union:      # Optional[...]
            args = [a for a in typing.get_args(hint)
                    if a is not type(None)]
            return st.one_of(st.none(), _strategy_for(args[0]))
        if origin in (tuple, typing.Tuple):
            args = typing.get_args(hint)
            if len(args) == 2 and args[1] is Ellipsis:
                return st.lists(_strategy_for(args[0]),
                                max_size=4).map(tuple)
            return st.tuples(*[_strategy_for(a) for a in args])
        if origin in (dict, typing.Dict):
            k, v = typing.get_args(hint)
            return st.dictionaries(_strategy_for(k), _strategy_for(v),
                                   max_size=4)
        if dataclasses.is_dataclass(hint):
            hints = typing.get_type_hints(hint)
            return st.builds(hint, **{f.name: _strategy_for(hints[f.name])
                                      for f in dataclasses.fields(hint)})
        raise AssertionError(f"no strategy for {hint!r}")

    def _message_strategy():
        choices = []
        for cls in P.MESSAGE_TYPES.values():
            hints = typing.get_type_hints(cls)
            choices.append(st.builds(
                cls, **{f.name: _strategy_for(hints[f.name])
                        for f in dataclasses.fields(cls)}))
        return st.one_of(choices)

    @given(msg=_message_strategy())
    @settings(max_examples=300, deadline=None)
    def test_fuzzed_messages_round_trip_byte_identically(msg):
        wire = P.dumps(msg)
        back = P.loads(wire)
        assert back == msg
        assert P.dumps(back) == wire

    @given(junk=st.dictionaries(
        st.text(max_size=10),
        st.one_of(st.integers(), st.text(max_size=10)),
        max_size=5))
    @settings(max_examples=200, deadline=None)
    def test_fuzzed_junk_dicts_never_crash_unhandled(junk):
        try:
            P.parse(junk)
        except P.ProtocolError:
            pass                        # the only acceptable failure mode
