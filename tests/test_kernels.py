"""Per-kernel allclose tests vs the ref.py oracles, sweeping shapes and
dtypes (interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(7)


def _tol(dtype):
    return 2e-2 if dtype == jnp.bfloat16 else 2e-5


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,H,K,Sq,Sk,D", [
    (2, 4, 2, 128, 128, 64),
    (1, 4, 1, 256, 256, 32),       # MQA
    (2, 2, 2, 96, 96, 16),         # ragged block
    (1, 8, 2, 1, 512, 64),         # decode shape
    (1, 2, 2, 64, 64, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_shapes_dtypes(B, H, K, Sq, Sk, D, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, H, Sq, D), dtype)
    k = jax.random.normal(ks[1], (B, K, Sk, D), dtype)
    v = jax.random.normal(ks[2], (B, K, Sk, D), dtype)
    out = ops.flash_attention(q, k, v, block_q=64, block_k=64)
    want = ref.attention_ref(q, k, v)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32),
        atol=_tol(dtype), rtol=_tol(dtype))


@pytest.mark.parametrize("window", [16, 64, 128])
def test_flash_attention_sliding_window(window):
    B, H, S, D = 1, 2, 256, 32
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, H, S, D))
    k = jax.random.normal(ks[1], (B, H, S, D))
    v = jax.random.normal(ks[2], (B, H, S, D))
    out = ops.flash_attention(q, k, v, window=window, block_q=32, block_k=32)
    want = ref.attention_ref(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5,
                               rtol=2e-5)


def test_flash_attention_softcap():
    B, H, S, D = 2, 2, 128, 32
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, H, S, D)) * 3
    k = jax.random.normal(ks[1], (B, H, S, D)) * 3
    v = jax.random.normal(ks[2], (B, H, S, D))
    out = ops.flash_attention(q, k, v, softcap=30.0, block_q=64, block_k=64)
    want = ref.attention_ref(q, k, v, softcap=30.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5,
                               rtol=2e-5)


# ---------------------------------------------------------------------------
# RG-LRU scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,L,bt,bl", [
    (2, 64, 32, 16, 16),
    (1, 100, 48, 32, 32),          # ragged both dims
    (3, 128, 256, 128, 128),
    (1, 7, 8, 8, 8),               # shorter than one block
])
def test_rglru_scan(B, S, L, bt, bl):
    ks = jax.random.split(KEY, 3)
    log_a = -jnp.exp(jax.random.normal(ks[0], (B, S, L)) * 0.5 - 2)
    b = jax.random.normal(ks[1], (B, S, L))
    h0 = jax.random.normal(ks[2], (B, L))
    out = ops.rglru_scan(log_a, b, h0, block_t=bt, block_l=bl)
    want = ref.rglru_ref(log_a, b, h0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-5,
                               rtol=1e-5)


def test_rglru_scan_no_initial_state():
    B, S, L = 2, 32, 16
    ks = jax.random.split(KEY, 2)
    log_a = -jnp.exp(jax.random.normal(ks[0], (B, S, L)) * 0.3 - 2)
    b = jax.random.normal(ks[1], (B, S, L))
    out = ops.rglru_scan(log_a, b, None, block_t=8, block_l=8)
    want = ref.rglru_ref(log_a, b, None)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-5,
                               rtol=1e-5)


# ---------------------------------------------------------------------------
# RWKV-6 WKV
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,H,N,C", [
    (2, 33, 2, 16, 8),             # ragged time
    (1, 64, 4, 64, 32),
    (2, 100, 3, 32, 32),
    (1, 16, 1, 8, 16),             # chunk > S
])
def test_wkv_chunked_kernel(B, S, H, N, C):
    ks = jax.random.split(KEY, 6)
    r = jax.random.normal(ks[0], (B, S, H, N)) * 0.5
    k = jax.random.normal(ks[1], (B, S, H, N)) * 0.5
    v = jax.random.normal(ks[2], (B, S, H, N)) * 0.5
    logw = -jnp.exp(jax.random.normal(ks[3], (B, S, H, N)) * 0.5 - 1.5)
    u = jax.random.normal(ks[4], (H, N)) * 0.5
    s0 = jax.random.normal(ks[5], (B, H, N, N)) * 0.1
    y, st = ops.wkv(r, k, v, logw, u, s0, chunk=C)
    yw, stw = ref.wkv_ref(r, k, v, logw, u, s0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yw), atol=5e-4,
                               rtol=5e-4)
    np.testing.assert_allclose(np.asarray(st), np.asarray(stw), atol=5e-4,
                               rtol=5e-4)


def test_wkv_model_chunked_matches_sequential():
    """The model's pure-jnp chunked WKV equals the sequential oracle."""
    from repro.models.rwkv6 import wkv_chunked_ref
    B, S, H, N = 2, 48, 2, 16
    ks = jax.random.split(KEY, 5)
    r = jax.random.normal(ks[0], (B, S, H, N)) * 0.5
    k = jax.random.normal(ks[1], (B, S, H, N)) * 0.5
    v = jax.random.normal(ks[2], (B, S, H, N)) * 0.5
    logw = -jnp.exp(jax.random.normal(ks[3], (B, S, H, N)) * 0.5 - 1.5)
    u = jax.random.normal(ks[4], (H, N)) * 0.5
    y, st = wkv_chunked_ref(r, k, v, logw, u, chunk=16)
    yw, stw = ref.wkv_ref(r, k, v, logw, u)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yw), atol=5e-4,
                               rtol=5e-4)
    np.testing.assert_allclose(np.asarray(st), np.asarray(stw), atol=5e-4,
                               rtol=5e-4)


# ---------------------------------------------------------------------------
# grouped GEMM
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("E,C,D,F", [
    (4, 32, 16, 24),
    (8, 128, 64, 128),
    (3, 100, 48, 60),              # ragged everything
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_group_gemm(E, C, D, F, dtype):
    ks = jax.random.split(KEY, 3)
    x = jax.random.normal(ks[0], (E, C, D), dtype)
    w = jax.random.normal(ks[1], (E, D, F), dtype)
    n = jax.random.randint(ks[2], (E,), 0, C + 1)
    out = ops.group_gemm(x, w, n)
    want = ref.group_gemm_ref(x, w, n)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=_tol(dtype) * 10, rtol=_tol(dtype) * 10)


def test_group_gemm_zero_valid_rows():
    E, C, D, F = 3, 16, 8, 8
    x = jnp.ones((E, C, D))
    w = jnp.ones((E, D, F))
    n = jnp.array([0, 16, 5])
    out = np.asarray(ops.group_gemm(x, w, n))
    assert (out[0] == 0).all()
    assert (out[1] != 0).all()
    assert (out[2, 5:] == 0).all() and (out[2, :5] != 0).all()
