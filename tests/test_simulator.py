"""Deterministic DES core: trace reproducibility, failure bookkeeping,
and clock semantics at the run(until=...) boundary."""
import math

import pytest

from repro.core import (FailureProcess, ResourceDirectory, ResourceSpec,
                        Simulator)

HOUR = 3600.0


def _flaky_directory(n=5, mtbf=2.0, mttr=0.5):
    d = ResourceDirectory()
    for i in range(n):
        d.register(ResourceSpec(name=f"r{i}", site="x", chips=1,
                                mtbf_hours=mtbf, mttr_hours=mttr))
    return d


def _failure_trace(seed, until=50 * HOUR):
    sim = Simulator()
    directory = _flaky_directory()
    trace = []
    fp = FailureProcess(sim, directory, seed=seed,
                        on_down=lambda r: trace.append((sim.now, "down", r)),
                        on_up=lambda r: trace.append((sim.now, "up", r)))
    for name in directory.all_names():
        fp.install(name)
    sim.run(until=until)
    return trace


def test_identical_seeds_identical_event_traces():
    t1 = _failure_trace(seed=11)
    t2 = _failure_trace(seed=11)
    assert t1, "no failures in 50 virtual hours at mtbf=2h?"
    assert t1 == t2           # timestamps AND order, exactly
    t3 = _failure_trace(seed=12)
    assert t1 != t3


def test_failure_process_never_double_fails_a_down_resource():
    trace = _failure_trace(seed=3, until=200 * HOUR)
    last = {}
    for _, kind, r in trace:
        assert last.get(r) != kind, f"{r} got two {kind!r} in a row"
        last[r] = kind
    # every resource's trace alternates starting with "down"
    firsts = {}
    for _, kind, r in trace:
        firsts.setdefault(r, kind)
    assert set(firsts.values()) == {"down"}


def test_externally_downed_resource_is_not_refailed():
    """The renewal process checks ``up`` before declaring a failure: a
    resource already down (e.g. by an operator) must not fire on_down
    again — the next event it emits is the repair."""
    sim = Simulator()
    directory = _flaky_directory(n=1, mtbf=1.0)
    trace = []
    fp = FailureProcess(sim, directory, seed=0,
                        on_down=lambda r: trace.append("down"),
                        on_up=lambda r: trace.append("up"))
    fp.install("r0")
    directory.status("r0").up = False        # operator takes it down
    sim.run(until=20 * HOUR)
    assert trace, "renewal process went silent"
    assert trace[0] == "up"                  # the swallowed double-fail
    assert all(a != b for a, b in zip(trace, trace[1:]))  # alternates


def test_run_until_executes_boundary_event_and_advances_clock():
    sim = Simulator()
    fired = []
    sim.at(10.0, lambda: fired.append("at10"))
    sim.at(25.0, lambda: fired.append("at25"))
    sim.run(until=10.0)
    assert fired == ["at10"]                 # t == until executes
    assert sim.now == 10.0                   # clock stops AT the boundary
    sim.run(until=20.0)
    assert fired == ["at10"]                 # 25.0 is beyond the horizon
    assert sim.now == 20.0                   # ...but the clock advances
    sim.run(until=30.0)
    assert fired == ["at10", "at25"]
    assert sim.now == 25.0                   # heap drained: last event time


def test_same_timestamp_events_fire_in_insertion_order():
    sim = Simulator()
    order = []
    for i in range(5):
        sim.at(7.0, lambda i=i: order.append(i))
    sim.run()
    assert order == [0, 1, 2, 3, 4]


def test_scheduling_into_the_past_raises():
    sim = Simulator()
    sim.at(5.0, lambda: None)
    sim.run()
    assert sim.now == 5.0
    with pytest.raises(ValueError):
        sim.at(4.0, lambda: None)
    sim.after(-10.0, lambda: None)           # clamped to "now", not an error
    sim.run()
    assert sim.now == 5.0


def test_stop_halts_immediately():
    sim = Simulator()
    seen = []
    sim.at(1.0, lambda: (seen.append(1), sim.stop()))
    sim.at(2.0, lambda: seen.append(2))
    sim.run(until=math.inf)
    assert seen == [1]


def test_every_fires_on_interval_until_bound():
    """Recurring events (auction clearing rounds) fire at exact interval
    multiples and respect the ``until`` bound."""
    sim = Simulator()
    ticks = []
    sim.every(10.0, lambda: ticks.append(sim.now), until=45.0)
    sim.run(until=100.0)
    assert ticks == [10.0, 20.0, 30.0, 40.0]


def test_every_start_delay_and_stop_value():
    sim = Simulator()
    ticks = []

    def fire():
        ticks.append(sim.now)
        return len(ticks) >= 3          # truthy return ends the series

    sim.every(5.0, fire, start_delay=0.0)
    sim.run(until=1000.0)
    assert ticks == [0.0, 5.0, 10.0]
    with pytest.raises(ValueError):
        sim.every(0.0, lambda: None)
