"""Deterministic DES core: trace reproducibility, failure bookkeeping,
and clock semantics at the run(until=...) boundary."""
import math

import pytest

from repro.core import (FailureProcess, ResourceDirectory, ResourceSpec,
                        Simulator)

HOUR = 3600.0


def _flaky_directory(n=5, mtbf=2.0, mttr=0.5):
    d = ResourceDirectory()
    for i in range(n):
        d.register(ResourceSpec(name=f"r{i}", site="x", chips=1,
                                mtbf_hours=mtbf, mttr_hours=mttr))
    return d


def _failure_trace(seed, until=50 * HOUR):
    sim = Simulator()
    directory = _flaky_directory()
    trace = []
    fp = FailureProcess(sim, directory, seed=seed,
                        on_down=lambda r: trace.append((sim.now, "down", r)),
                        on_up=lambda r: trace.append((sim.now, "up", r)))
    for name in directory.all_names():
        fp.install(name)
    sim.run(until=until)
    return trace


def test_identical_seeds_identical_event_traces():
    t1 = _failure_trace(seed=11)
    t2 = _failure_trace(seed=11)
    assert t1, "no failures in 50 virtual hours at mtbf=2h?"
    assert t1 == t2           # timestamps AND order, exactly
    t3 = _failure_trace(seed=12)
    assert t1 != t3


def test_failure_process_never_double_fails_a_down_resource():
    trace = _failure_trace(seed=3, until=200 * HOUR)
    last = {}
    for _, kind, r in trace:
        assert last.get(r) != kind, f"{r} got two {kind!r} in a row"
        last[r] = kind
    # every resource's trace alternates starting with "down"
    firsts = {}
    for _, kind, r in trace:
        firsts.setdefault(r, kind)
    assert set(firsts.values()) == {"down"}


def test_externally_downed_resource_is_not_refailed():
    """The renewal process checks ``up`` before declaring a failure: a
    resource already down (e.g. by an operator) must not fire on_down
    again — the next event it emits is the repair."""
    sim = Simulator()
    directory = _flaky_directory(n=1, mtbf=1.0)
    trace = []
    fp = FailureProcess(sim, directory, seed=0,
                        on_down=lambda r: trace.append("down"),
                        on_up=lambda r: trace.append("up"))
    fp.install("r0")
    directory.status("r0").up = False        # operator takes it down
    sim.run(until=20 * HOUR)
    assert trace, "renewal process went silent"
    assert trace[0] == "up"                  # the swallowed double-fail
    assert all(a != b for a, b in zip(trace, trace[1:]))  # alternates


def test_run_until_executes_boundary_event_and_advances_clock():
    sim = Simulator()
    fired = []
    sim.at(10.0, lambda: fired.append("at10"))
    sim.at(25.0, lambda: fired.append("at25"))
    sim.run(until=10.0)
    assert fired == ["at10"]                 # t == until executes
    assert sim.now == 10.0                   # clock stops AT the boundary
    sim.run(until=20.0)
    assert fired == ["at10"]                 # 25.0 is beyond the horizon
    assert sim.now == 20.0                   # ...but the clock advances
    sim.run(until=30.0)
    assert fired == ["at10", "at25"]
    assert sim.now == 25.0                   # heap drained: last event time


def test_same_timestamp_events_fire_in_insertion_order():
    sim = Simulator()
    order = []
    for i in range(5):
        sim.at(7.0, lambda i=i: order.append(i))
    sim.run()
    assert order == [0, 1, 2, 3, 4]


def test_scheduling_into_the_past_raises():
    sim = Simulator()
    sim.at(5.0, lambda: None)
    sim.run()
    assert sim.now == 5.0
    with pytest.raises(ValueError):
        sim.at(4.0, lambda: None)
    sim.after(-10.0, lambda: None)           # clamped to "now", not an error
    sim.run()
    assert sim.now == 5.0


def test_stop_halts_immediately():
    sim = Simulator()
    seen = []
    sim.at(1.0, lambda: (seen.append(1), sim.stop()))
    sim.at(2.0, lambda: seen.append(2))
    sim.run(until=math.inf)
    assert seen == [1]


def test_every_fires_on_interval_until_bound():
    """Recurring events (auction clearing rounds) fire at exact interval
    multiples and respect the ``until`` bound."""
    sim = Simulator()
    ticks = []
    sim.every(10.0, lambda: ticks.append(sim.now), until=45.0)
    sim.run(until=100.0)
    assert ticks == [10.0, 20.0, 30.0, 40.0]


def test_every_start_delay_and_stop_value():
    sim = Simulator()
    ticks = []

    def fire():
        ticks.append(sim.now)
        return len(ticks) >= 3          # truthy return ends the series

    sim.every(5.0, fire, start_delay=0.0)
    sim.run(until=1000.0)
    assert ticks == [0.0, 5.0, 10.0]
    with pytest.raises(ValueError):
        sim.every(0.0, lambda: None)


# ---------------------------------------------------------------------------
# calendar-queue internals: ordering equivalence and dead-timer bounds
# ---------------------------------------------------------------------------

def test_calendar_order_matches_global_time_seq_order():
    """Whatever buckets/overflow pages events land in, they must fire
    in the exact (t, seq) lexicographic order a single heap gives —
    including ties, epsilon-past schedules, and far-future overflow
    entries pulled back in across page advances."""
    import random as _random
    rng = _random.Random(42)
    sim = Simulator(bucket_width=10.0, wheel_buckets=8)  # tiny wheel:
    # horizon = 80s, so most of the schedule lives in overflow pages
    fired = []
    expect = []
    handles = []
    for i in range(500):
        # cluster times to force same-bucket ties and exact duplicates
        t = rng.choice([rng.uniform(0, 5000), float(rng.randrange(100))])
        h = sim.at(t, lambda i=i: fired.append(i))
        handles.append((t, i, h))
    cancelled = set()
    for t, i, h in rng.sample(handles, 150):
        h.cancel()
        cancelled.add(i)
    expect = [i for t, i, h in sorted(handles, key=lambda x: (x[0], x[1]))
              if i not in cancelled]
    sim.run()
    assert fired == expect


def test_calendar_mid_run_scheduling_preserves_order():
    """Events scheduled from inside callbacks — including zero-delay
    and into the bucket currently being drained — still interleave in
    exact (t, seq) order."""
    sim = Simulator(bucket_width=10.0, wheel_buckets=4)
    log = []

    def fire(tag):
        log.append((sim.now, tag))
        if tag == "a":
            sim.after(0.0, lambda: fire("a0"))      # same instant
            sim.after(3.0, lambda: fire("a3"))      # same bucket
            sim.after(500.0, lambda: fire("a500"))  # beyond the wheel

    sim.at(5.0, lambda: fire("a"))
    sim.at(5.0, lambda: fire("b"))       # later seq, same t: after "a"
    sim.at(7.0, lambda: fire("c"))
    sim.run()
    assert log == [(5.0, "a"), (5.0, "b"), (5.0, "a0"), (7.0, "c"),
                   (8.0, "a3"), (505.0, "a500")]


def test_cancelled_timers_never_dominate_the_queue():
    """The leak regression: 10k schedule/cancel cycles must not leave
    10k corpses — compaction holds stored entries to O(live)."""
    sim = Simulator()
    keep = [sim.at(float(i), lambda: None) for i in range(100)]
    dead = [sim.at(1e6 + i, lambda: None) for i in range(10_000)]
    for h in dead:
        h.cancel()
    assert sim.pending_events() == 100
    # compaction invariant: dead never exceed half the store (+ the
    # small-queue grace), so stored entries stay O(live)
    assert sim._size <= 2 * sim.pending_events() + 66
    assert sim._size < 1000          # nowhere near the 10_100 scheduled
    for h in keep:
        assert not h.cancelled


def test_churny_run_keeps_queue_bounded():
    """End-to-end: a churny multi-broker run (straggler duplicates,
    evictions, timer cancels everywhere) samples the queue every tick
    — stored entries must track the live count, not history."""
    from repro.core import standard_market
    market = standard_market(4, n_machines=12, seed=5, n_jobs=40,
                             gis_ttl=900.0, churn_mean_uptime_h=3.0,
                             churn_mean_downtime_h=1.0)
    sim = market.sim
    worst = []
    sim.every(60.0, lambda: worst.append(
        (sim._size, sim.pending_events())))
    market.run(failures=True, churn=True)
    assert worst, "sampler never fired"
    for size, live in worst:
        assert size <= 2 * live + 66, (size, live)
