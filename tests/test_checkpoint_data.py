"""Checkpointing (atomicity, crc, resharding restore) + data determinism."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step_dir, load_metadata, restore, save
from repro.data import DataConfig, SyntheticLM


def _tree(key):
    k1, k2 = jax.random.split(key)
    return {"a": jax.random.normal(k1, (8, 16)),
            "nested": {"b": jax.random.normal(k2, (4,)),
                       "c": jnp.arange(6, dtype=jnp.int32)},
            "lst": [jnp.ones((2, 2)), jnp.zeros((3,))]}


def test_save_restore_roundtrip(tmp_path):
    t = _tree(jax.random.PRNGKey(0))
    d = save(str(tmp_path / "ck"), t, metadata={"step": 7})
    assert load_metadata(d)["step"] == 7
    abstract = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), t)
    r = restore(d, abstract)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(r)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_atomic_overwrite_and_latest(tmp_path):
    t = _tree(jax.random.PRNGKey(1))
    root = str(tmp_path / "run")
    save(os.path.join(root, "step_0000010"), t, metadata={"step": 10})
    save(os.path.join(root, "step_0000020"), t, metadata={"step": 20})
    assert latest_step_dir(root).endswith("step_0000020")
    # overwrite same step: still valid afterwards
    save(os.path.join(root, "step_0000020"), t, metadata={"step": 20})
    assert load_metadata(latest_step_dir(root))["step"] == 20


def test_crc_detects_corruption(tmp_path):
    t = _tree(jax.random.PRNGKey(2))
    d = save(str(tmp_path / "ck"), t)
    shard = [f for f in os.listdir(d) if f.startswith("shard_")][0]
    path = os.path.join(d, shard)
    raw = bytearray(open(path, "rb").read())
    raw[len(raw) // 2] ^= 0xFF
    open(path, "wb").write(bytes(raw))
    abstract = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), t)
    with pytest.raises(IOError, match="crc32"):
        restore(d, abstract)


def test_restore_shape_mismatch_raises(tmp_path):
    t = {"w": jnp.ones((4, 4))}
    d = save(str(tmp_path / "ck"), t)
    with pytest.raises(ValueError, match="shape"):
        restore(d, {"w": jax.ShapeDtypeStruct((4, 5), jnp.float32)})


def test_restore_missing_leaf_raises(tmp_path):
    d = save(str(tmp_path / "ck"), {"w": jnp.ones((2,))})
    with pytest.raises(KeyError):
        restore(d, {"w": jax.ShapeDtypeStruct((2,), jnp.float32),
                    "extra": jax.ShapeDtypeStruct((2,), jnp.float32)})


# -- data pipeline ----------------------------------------------------------

def test_batches_deterministic_in_step_and_shard():
    cfg = DataConfig(vocab_size=1000, seq_len=64, global_batch=8, seed=3)
    d1, d2 = SyntheticLM(cfg), SyntheticLM(cfg)
    b1 = d1.batch(5, shard=1, n_shards=4)
    b2 = d2.batch(5, shard=1, n_shards=4)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = d1.batch(6, shard=1, n_shards=4)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    b4 = d1.batch(5, shard=2, n_shards=4)
    assert not np.array_equal(b1["tokens"], b4["tokens"])


def test_shards_partition_global_batch():
    cfg = DataConfig(vocab_size=100, seq_len=16, global_batch=8, seed=0)
    d = SyntheticLM(cfg)
    full = d.batch(0, 0, 1)
    assert full["tokens"].shape == (8, 16)
    parts = [d.batch(0, s, 4) for s in range(4)]
    assert all(p["tokens"].shape == (2, 16) for p in parts)


def test_labels_are_next_tokens():
    cfg = DataConfig(vocab_size=100, seq_len=16, global_batch=2, seed=0)
    b = SyntheticLM(cfg).batch(0)
    assert b["tokens"].shape == b["labels"].shape
    # same underlying sequence shifted by one: overlapping region matches
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_embeddings_mode_shapes():
    cfg = DataConfig(vocab_size=2048, seq_len=32, global_batch=4, seed=0,
                     input_kind="embeddings", d_model=64)
    b = SyntheticLM(cfg).batch(0)
    assert b["embeds"].shape == (4, 32, 64)
    assert b["labels"].shape == (4, 32)
